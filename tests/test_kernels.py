"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs ref.py oracles.

All kernel outputs are integers (or masked floats), so comparisons are exact.
"""

import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import SpecDFAEngine, compile_regex, make_search_dfa, random_dfa
from repro.kernels import ops, ref


def _dfa(q, ncls, seed):
    return random_dfa(q, ncls, rng=np.random.default_rng(seed))


# --------------------------------------------------------------------------
# spec_match (gather kernel + MXU path)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("q,ncls,c,l,s", [
    (4, 2, 1, 16, 1),        # minimal
    (17, 5, 6, 384, 9),      # odd everything
    (64, 16, 8, 512, 16),    # aligned
    (130, 7, 3, 130, 130),   # S = Q (holub mode shape), prime-ish L
    (257, 26, 2, 1024, 33),  # Q > 256
])
def test_spec_match_gather_shapes(q, ncls, c, l, s):
    rng = np.random.default_rng(q * 1000 + l)
    dfa = _dfa(q, ncls, 1)
    table = jnp.asarray(dfa.table)
    chunks = jnp.asarray(rng.integers(0, ncls, size=(c, l), dtype=np.int32))
    init = jnp.asarray(rng.integers(0, q, size=(c, s), dtype=np.int32))
    want = np.asarray(ref.spec_match_ref(table, chunks, init))
    got = np.asarray(ops.spec_match(table, chunks, init, use_mxu=False))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("q,ncls,c,l,s", [
    (8, 3, 2, 64, 8),
    (32, 4, 4, 256, 32),
    (128, 8, 2, 512, 64),
])
def test_spec_match_mxu_shapes(q, ncls, c, l, s):
    rng = np.random.default_rng(q + l)
    dfa = _dfa(q, ncls, 2)
    table = jnp.asarray(dfa.table)
    chunks = jnp.asarray(rng.integers(0, ncls, size=(c, l), dtype=np.int32))
    init = jnp.asarray(rng.integers(0, q, size=(c, s), dtype=np.int32))
    want = np.asarray(ref.spec_match_ref(table, chunks, init))
    got = np.asarray(ops.spec_match(table, chunks, init, use_mxu=True))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(
    q=st.integers(2, 50),
    ncls=st.integers(2, 8),
    c=st.integers(1, 6),
    logl=st.integers(4, 9),
    s=st.integers(1, 20),
    seed=st.integers(0, 2**31 - 1),
)
def test_spec_match_property(q, ncls, c, logl, s, seed):
    rng = np.random.default_rng(seed)
    dfa = _dfa(q, ncls, seed)
    table = jnp.asarray(dfa.table)
    l = 2 ** logl
    chunks = jnp.asarray(rng.integers(0, ncls, size=(c, l), dtype=np.int32))
    init = jnp.asarray(rng.integers(0, q, size=(c, s), dtype=np.int32))
    want = np.asarray(ref.spec_match_ref(table, chunks, init))
    got = np.asarray(ops.spec_match(table, chunks, init, use_mxu=False))
    np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------------
# lvec_compose
# --------------------------------------------------------------------------

@pytest.mark.parametrize("c,q", [(1, 4), (8, 17), (16, 128), (7, 33), (24, 257)])
def test_lvec_compose_shapes(c, q):
    rng = np.random.default_rng(c * q)
    maps = jnp.asarray(rng.integers(0, q, size=(c, q), dtype=np.int32))
    want = np.asarray(ref.lvec_compose_ref(maps))
    got = np.asarray(ops.lvec_compose(maps))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(c=st.integers(1, 24), q=st.integers(2, 80), seed=st.integers(0, 2**31 - 1))
def test_lvec_compose_property(c, q, seed):
    rng = np.random.default_rng(seed)
    maps = jnp.asarray(rng.integers(0, q, size=(c, q), dtype=np.int32))
    np.testing.assert_array_equal(np.asarray(ops.lvec_compose(maps)),
                                  np.asarray(ref.lvec_compose_ref(maps)))


# --------------------------------------------------------------------------
# onehot_block_maps (MXU formulation exactness)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("q,ncls,l,blk", [
    (4, 2, 64, 16), (16, 4, 256, 64), (64, 8, 512, 128), (128, 16, 256, 256),
])
def test_onehot_block_maps_shapes(q, ncls, l, blk):
    rng = np.random.default_rng(q + l)
    dfa = _dfa(q, ncls, 3)
    table = jnp.asarray(dfa.table)
    syms = jnp.asarray(rng.integers(0, ncls, size=(l,), dtype=np.int32))
    want = np.asarray(ref.onehot_block_maps_ref(table, syms, blk))
    got = np.asarray(ops.onehot_block_maps(table, syms, block_l=blk))
    np.testing.assert_array_equal(got, want)


def test_onehot_exactness_worst_case():
    """Many-to-one transitions (non-permutation P) must stay exact in bf16."""
    q = 96
    table = np.zeros((q, 3), dtype=np.int32)  # every state -> 0 on class 0
    table[:, 1] = np.arange(q)                # identity on class 1
    table[:, 2] = (np.arange(q) + 1) % q      # cycle on class 2
    syms = jnp.asarray(np.tile([0, 1, 2, 2], 32).astype(np.int32))
    want = np.asarray(ref.onehot_block_maps_ref(jnp.asarray(table), syms, 64))
    got = np.asarray(ops.onehot_block_maps(jnp.asarray(table), syms, block_l=64))
    np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------------
# token_mask
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,q,v", [(1, 3, 2048), (5, 17, 4096), (8, 64, 2048)])
def test_token_mask_shapes(b, q, v, dtype):
    rng = np.random.default_rng(b * v)
    states = jnp.asarray(rng.integers(0, q, size=(b,), dtype=np.int32))
    allowed = jnp.asarray(rng.integers(0, 2, size=(q, v), dtype=np.uint8))
    logits = jnp.asarray(rng.normal(size=(b, v)).astype(np.float32)).astype(dtype)
    want = np.asarray(ref.token_mask_ref(states, allowed.astype(bool), logits))
    got = np.asarray(ops.token_mask(states, allowed, logits))
    np.testing.assert_array_equal(got, want)


def test_token_mask_ragged_vocab_pad():
    rng = np.random.default_rng(0)
    b, q, v = 3, 5, 3000  # not a multiple of any tile
    states = jnp.asarray(rng.integers(0, q, size=(b,), dtype=np.int32))
    allowed = jnp.asarray(rng.integers(0, 2, size=(q, v), dtype=np.uint8))
    logits = jnp.asarray(rng.normal(size=(b, v)).astype(np.float32))
    want = np.asarray(ref.token_mask_ref(states, allowed.astype(bool), logits))
    got = np.asarray(ops.token_mask(states, allowed, logits))
    np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------------
# kernel-backed engine end-to-end
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["lookahead", "basic", "holub"])
def test_engine_with_pallas_matcher(mode):
    dfa = make_search_dfa(compile_regex(r".*(ab|ba){2,3}[0-9]"))
    rng = np.random.default_rng(5)
    data = rng.choice(np.frombuffer(b"ab019xyz", np.uint8), size=4096)

    def pallas_matcher(table, chunks, init):
        return ops.spec_match(table, chunks, init, use_mxu=False)

    eng = SpecDFAEngine(dfa, num_chunks=8, mode=mode, matcher=pallas_matcher)
    ref_eng = SpecDFAEngine(dfa, num_chunks=8, mode=mode)
    assert eng.membership(data).final_state == ref_eng.membership(data).final_state


# --------------------------------------------------------------------------
# flash_attn (fused attention forward)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("bh,t,s,d,causal,window", [
    (2, 128, 128, 32, True, 0),
    (4, 256, 256, 64, True, 0),
    (2, 128, 128, 32, True, 48),    # sliding window
    (3, 64, 192, 16, False, 0),     # cross-attention shape
    (1, 384, 384, 128, True, 128),
])
def test_flash_attn_vs_ref(bh, t, s, d, causal, window):
    rng = np.random.default_rng(t + s + d)
    q = jnp.asarray(rng.normal(size=(bh, t, d)).astype(np.float32)).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(bh, s, d)).astype(np.float32)).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(bh, s, d)).astype(np.float32)).astype(jnp.bfloat16)
    want = np.asarray(ref.flash_attn_ref(q, k, v, causal=causal,
                                         window=window), np.float32)
    got = np.asarray(ops.flash_attn(q, k, v, causal=causal, window=window,
                                    q_blk=64, kv_blk=64), np.float32)
    np.testing.assert_allclose(got, want, atol=3e-2, rtol=3e-2)


def test_flash_attn_matches_model_attention_core():
    """Kernel semantics == the XLA flash path used by the models."""
    from repro.models.attention_core import flash_attention
    rng = np.random.default_rng(0)
    b, t, n_kv, g, h = 2, 256, 2, 2, 32
    q = jnp.asarray(rng.normal(size=(b, t, n_kv, g, h)).astype(np.float32)).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, t, n_kv, h)).astype(np.float32)).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, t, n_kv, h)).astype(np.float32)).astype(jnp.bfloat16)
    want = flash_attention(q, k, v, causal=True, q_block=64, kv_block=64)
    # flatten (b, kv, g) into BH and expand kv for the kernel layout
    qf = q.transpose(0, 2, 3, 1, 4).reshape(b * n_kv * g, t, h)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * n_kv * g, t, h)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * n_kv * g, t, h)
    got = ops.flash_attn(qf, kf, vf, causal=True, q_blk=64, kv_blk=64)
    got = got.reshape(b, n_kv, g, t, h).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=3e-2, rtol=3e-2)


def test_prefill_with_pallas_attention_matches_xla(monkeypatch):
    """REPRO_PALLAS_ATTN=1 routes prefill through the fused kernel (interpret
    mode on CPU) and must match the XLA flash path end to end."""
    import os
    import jax
    from repro.configs import ShapeSpec, get_config, reduce_for_smoke
    from repro.models import api

    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    params = api.init(cfg, jax.random.PRNGKey(0))
    batch = api.make_inputs(cfg, ShapeSpec("p", "prefill", 64, 2), seed=1)

    logits_xla, _ = api.prefill(params, cfg, batch)
    monkeypatch.setenv("REPRO_PALLAS_ATTN", "1")
    logits_pl, _ = api.prefill(params, cfg, batch)
    np.testing.assert_allclose(np.asarray(logits_pl, np.float32),
                               np.asarray(logits_xla, np.float32),
                               atol=5e-2, rtol=5e-2)
