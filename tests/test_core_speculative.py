"""Property tests for the speculative matcher: the paper's central claims.

  * sequential semantics are maintained for every mode / chunking  (Sec. 1)
  * speculation is failure-free: per-processor work never exceeds the
    balanced bound                                                   (Sec. 4.4)
  * Lemma 1: I_max,r monotonically non-increasing in r
  * L-vector composition is associative; all merge strategies agree  (Eq. 8/9)
"""

import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (SpecDFAEngine, build_lookahead_tables, compile_regex,
                        compose, i_max_r, identity_lvec, make_search_dfa,
                        merge_scan_jnp, merge_sequential, merge_tree,
                        random_dfa, uniform_partition, weighted_partition)

MODES = ("lookahead", "basic", "holub")


@settings(max_examples=40, deadline=None)
@given(
    n_states=st.integers(3, 40),
    n_classes=st.integers(2, 10),
    n=st.integers(0, 600),
    chunks=st.integers(2, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_speculative_equals_sequential_random_dfa(n_states, n_classes, n, chunks, seed):
    rng = np.random.default_rng(seed)
    dfa = random_dfa(n_states, n_classes, rng=rng)
    data = rng.integers(0, 256, size=n, dtype=np.uint8)
    want = dfa.run(data)
    for mode in MODES:
        for part in ("balanced", "uniform"):
            eng = SpecDFAEngine(dfa, num_chunks=chunks, mode=mode, partition=part)
            got = eng.membership(data)
            assert got.final_state == want, (mode, part, n, chunks)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("pattern", [r".*(ab|ba){2,4}", r".*[0-9]{3}[a-z]", r"a*b+c{2,5}"])
def test_speculative_equals_sequential_regex(mode, pattern):
    dfa = make_search_dfa(compile_regex(pattern))
    rng = np.random.default_rng(1)
    data = bytes(rng.choice(list(b"ab0123cxyz"), size=4000))
    eng = SpecDFAEngine(dfa, num_chunks=8, mode=mode)
    assert eng.membership(data).final_state == eng.membership_sequential(data).final_state


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 30), st.integers(2, 6), st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_lemma1_imax_monotone(n_states, n_classes, r, seed):
    rng = np.random.default_rng(seed)
    dfa = random_dfa(n_states, n_classes, rng=rng)
    vals = i_max_r(dfa, r)
    assert all(vals[i] >= vals[i + 1] for i in range(len(vals) - 1))
    # dedup BFS must agree with the paper's exponential enumeration
    if n_classes ** r * n_states <= 20_000:
        assert vals == i_max_r(dfa, r, method="enum")


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 20), st.integers(2, 10), st.integers(0, 2**31 - 1))
def test_lvector_merges_agree(n_maps, q, seed):
    rng = np.random.default_rng(seed)
    lvecs = rng.integers(0, q, size=(n_maps, q)).astype(np.int32)
    seq = merge_sequential(lvecs, 0)
    tree = merge_tree(lvecs)
    scan = np.asarray(merge_scan_jnp(jnp.asarray(lvecs)))[-1]
    assert int(tree[0]) == seq
    assert int(scan[0]) == seq
    np.testing.assert_array_equal(tree, scan)


def test_lvector_associativity_and_identity():
    rng = np.random.default_rng(0)
    q = 11
    a, b, c = (rng.integers(0, q, size=q).astype(np.int32) for _ in range(3))
    np.testing.assert_array_equal(compose(compose(a, b), c), compose(a, compose(b, c)))
    ident = identity_lvec(q)
    np.testing.assert_array_equal(compose(ident, a), a)
    np.testing.assert_array_equal(compose(a, ident), a)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(10, 100_000),
    p=st.integers(1, 64),
    m=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_partition_covers_input_and_balances(n, p, m, seed):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.5, 2.0, size=p)
    w = w / w.mean()
    part = weighted_partition(n, w, m)
    # exact cover, in order, no overlap
    assert part.start[0] == 0 and part.end[-1] == n
    assert (part.start[1:] == part.end[:-1]).all()
    assert (part.sizes >= 0).all()
    # failure-freedom (Eq. 2/5): weighted per-processor time is balanced up to
    # rounding: |time_k - mean| <= m/w_k symbols' worth of work.
    if p > 1 and n >= p * m * 4:
        times = part.work() / w
        slack = (m / w) + 2
        assert (np.abs(times - times.mean()) <= slack * 2).all()


def test_uniform_partition_exact():
    part = uniform_partition(100, 7, m=3)
    assert part.start[0] == 0 and part.end[-1] == 100
    assert (part.start[1:] == part.end[:-1]).all()
    assert part.sizes.sum() == 100


def test_failure_freedom_work_bound():
    """Parallel work per processor never exceeds sequential total (Sec. 4.4).

    Holds for the paper's balanced partition: work = max(L0, L_spec * m)
    <= n (up to rounding).  Also checks the speedup trend 1 + (P-1)/m.
    """
    dfa = make_search_dfa(compile_regex(r".*(foo|bar)[0-9]{2}"))
    rng = np.random.default_rng(3)
    data = rng.choice(np.frombuffer(b"fobar019xyz", np.uint8), size=20_000)
    prev_speedup = 0.0
    for chunks in (2, 4, 8, 16):
        eng = SpecDFAEngine(dfa, num_chunks=chunks, mode="lookahead",
                            partition="balanced")
        res = eng.membership(data)
        assert res.work_parallel <= res.work_sequential + chunks * eng.i_max
        assert res.final_state == eng.membership_sequential(data).final_state
        assert res.model_speedup >= prev_speedup * 0.95  # monotone-ish in P
        prev_speedup = res.model_speedup
    # Eq. 15/18: speedup ~ 1 + (P-1)/I_max within rounding for the last run
    expect = 1 + (16 - 1) / eng.i_max
    assert abs(res.model_speedup - expect) / expect < 0.25


def test_uniform_partition_lane_model_speedup():
    """Uniform chunks: wall-clock steps = n/C in the lane-parallel model."""
    dfa = make_search_dfa(compile_regex(r".*(foo|bar)[0-9]{2}"))
    rng = np.random.default_rng(4)
    data = rng.choice(np.frombuffer(b"fobar019xyz", np.uint8), size=16_000)
    eng = SpecDFAEngine(dfa, num_chunks=8, mode="lookahead", partition="uniform")
    res = eng.membership(data)
    assert res.final_state == eng.membership_sequential(data).final_state
    assert res.time_steps <= 16_000 // 8 + 8


def test_lookahead_tables_cover_all_transition_targets():
    dfa = make_search_dfa(compile_regex(r".*(ab|ba){2}"))
    tabs = build_lookahead_tables(dfa)
    for c in range(dfa.n_classes):
        targets = {int(t) for t in dfa.table[:, c]} - {dfa.sink}
        listed = {int(s) for s in tabs.candidates[c, : int(tabs.cand_count[c])]}
        assert targets == listed
        for q in targets:
            assert int(tabs.cand_index[c, q]) >= 0


@settings(max_examples=25, deadline=None)
@given(
    n_states=st.integers(3, 30),
    n_classes=st.integers(2, 8),
    n=st.integers(0, 500),
    chunks=st.integers(2, 7),
    seed=st.integers(0, 2**31 - 1),
)
def test_lookahead_r2_equals_sequential(n_states, n_classes, n, chunks, seed):
    """Runtime 2-symbol reverse lookahead (Sec. 4.3) preserves semantics."""
    rng = np.random.default_rng(seed)
    dfa = random_dfa(n_states, n_classes, rng=rng)
    data = rng.integers(0, 256, size=n, dtype=np.uint8)
    want = dfa.run(data)
    for part in ("balanced", "uniform"):
        eng = SpecDFAEngine(dfa, num_chunks=chunks, lookahead_r=2,
                            partition=part)
        assert eng.membership(data).final_state == want, (part, n, chunks)


def test_lookahead_r2_never_worse_than_r1():
    """Lemma 1 at runtime: I_max,2 <= I_max,1 -> work-model speedup >=."""
    dfa = make_search_dfa(compile_regex(r".*(ab|ba){2,4}[0-9]{2}"))
    rng = np.random.default_rng(9)
    data = rng.choice(np.frombuffer(b"ab0123xyz", np.uint8), size=30_000)
    e1 = SpecDFAEngine(dfa, num_chunks=16, lookahead_r=1)
    e2 = SpecDFAEngine(dfa, num_chunks=16, lookahead_r=2)
    r1, r2 = e1.membership(data), e2.membership(data)
    assert r1.final_state == r2.final_state
    assert e2.i_max <= e1.i_max
    assert r2.model_speedup >= r1.model_speedup * 0.999
