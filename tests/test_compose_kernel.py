"""PR 10 perf tier: the batched scan-compose Pallas kernel, ragged
capacity-weighted doc tiling, and observed-traffic autotuning.

Covers the PR's guarantees end to end:

  * ``ops.spec_compose_lanes`` (both the block-sequential grid-carry kernel
    and the in-kernel Blelloch tree) is bit-identical to
    ``ref.spec_compose_lanes_ref`` on *real* candidate tables — the compose
    combine is associative only when sinks absorb, so random tables would
    be a vacuous oracle — under r=1 and r=2 keys and ragged
    (right-pad_key-padded) run lengths;
  * ``Matcher.compose_lane_maps`` lowers to the kernel on the pallas
    backend (``("compose_kernel", N)``, visible in ``perf_report()``), to
    the jnp associative scan everywhere else, and every lowering agrees
    bit-for-bit across backends and mesh shapes;
  * ``MeshLayout.doc_counts`` / ``tile_rows`` apply Eq. 7 to the document
    axis: capacity-proportional placement into fixed physical row-blocks,
    degrading to positional packing on uniform layouts, and the sharded
    matcher's results are bit-identical with and without ragged placement
    (seeded and under hypothesis when installed);
  * ``TrafficProfile`` / ``ObservedTraffic`` accumulate per-dispatch
    (batch, lengths) samples, ``drift`` measures log2 distance, and
    ``Matcher.maybe_retune`` re-tunes on the observed distribution once it
    drifts — applying ``l_blk`` in place and invalidating the spec-kernel
    lowerings so the next dispatch recompiles at the tuned shape.
"""

import numpy as np
import pytest

from repro.core import Matcher, compile_regex, make_search_dfa
from repro.core.engine.plan import MeshLayout, ChunkLayout
from repro.core.partition import capacity_weights
from repro.core.profiling import (ObservedTraffic, TrafficProfile,
                                  clear_autotune_cache, synthetic_traffic)
from repro.kernels import ops, ref

PATTERNS = [".*(ab|ba){2}", ".*[0-9]{3}", ".*x+y"]
ALPHABET = list(b"abxy0189")


def _matcher(backend="local", r="auto", **kw):
    dfas = [make_search_dfa(compile_regex(p)) for p in PATTERNS]
    kw.setdefault("num_chunks", 2)
    kw.setdefault("batch_tile", 8)
    return Matcher(dfas, backend=backend, lookahead_r=r, **kw)


def _lane_runs(m, rng, lens, seg_len=48):
    """Real-table lane-map runs from random traffic over ALPHABET.

    ``lens[i]`` is row i's run length; rows shorter than ``max(lens)``
    right-pad with ``pad_key`` identities (zero maps, never read).
    Returns ``maps [B, N, K, S]`` and ``keys [B, N]``.
    """
    b, n = len(lens), max(lens)
    k, s = m.packed.n_patterns, m.dev.tables.i_max
    cands = np.asarray(m.dev.tables.candidates, np.int32)
    maps = np.zeros((b, n, k, s), np.int32)
    keys = np.full((b, n), m.dev.pad_key, np.int32)
    segs, flat_keys, where = [], [], []
    for i in range(b):
        data = bytes(rng.choice(ALPHABET, size=2 + lens[i] * seg_len)
                     .astype(np.uint8))
        key = m.dev.advance_key(-1, data[:2])
        for j in range(lens[i]):
            p = data[2 + j * seg_len:2 + (j + 1) * seg_len]
            segs.append(p)
            flat_keys.append(key)
            where.append((i, j))
            keys[i, j] = key
            key = m.dev.advance_key(key, p)
    fk = np.asarray(flat_keys, np.int32)
    res = m.advance_cursors(segs, np.ascontiguousarray(cands[fk]), fk)
    for (i, j), lm in zip(where, np.asarray(res.lane_states, np.int32)):
        maps[i, j] = lm
    return maps, keys


def _mask_pad_lanes(m, out, keys0, fill=-7):
    """Restrict composed maps to the lanes the contract covers.

    A composed run's entry axis is keyed on its first element's boundary
    key; consumers always select a lane through ``cand_index``, which only
    ever addresses *real* candidate lanes.  Pad lanes (duplicated filler
    states that are not candidates of the key) hold passthrough values that
    depend on evaluation order — sequential folds and tree reductions
    legitimately disagree there, never on a readable lane.
    """
    t = m.dev.tables
    cidx = np.asarray(t.cand_index)
    cands = np.asarray(t.candidates)
    b, (k, s) = len(keys0), cands.shape[1:]
    mask = (np.take_along_axis(cidx[keys0], cands[keys0].reshape(b, -1),
                               axis=1).reshape(b, k, s)
            == np.arange(s))
    return np.where(mask, out, fill)


# --------------------------------------------------------------------------
# ops-level: both kernels vs the sequential-fold oracle, real tables
# --------------------------------------------------------------------------

@pytest.mark.parametrize("r", [1, 2])
@pytest.mark.parametrize("mode", ["carry", "tree"])
def test_spec_compose_lanes_matches_ref(mode, r):
    rng = np.random.default_rng(80 + r)
    m = _matcher("local", r)
    for lens in ([4, 4, 4], [1, 5, 3, 7], [2], [6, 1]):  # ragged runs
        maps, keys = _lane_runs(m, rng, lens)
        want = np.asarray(ref.spec_compose_lanes_ref(
            maps, keys, np.asarray(m.dev.cidx_pad_j),
            np.asarray(m.packed.sinks), pad_cls=m.dev.pad_key))
        got = np.asarray(ops.spec_compose_lanes(
            maps, keys, m.dev.cidx_pad_j, m.dev.sinks_j,
            pad_key=m.dev.pad_key, mode=mode))
        if mode == "carry":
            # the grid-carry kernel is a sequential left fold, like the
            # oracle: every lane agrees, even unreadable pad lanes
            np.testing.assert_array_equal(got, want, err_msg=f"carry r={r}")
        np.testing.assert_array_equal(
            _mask_pad_lanes(m, got, keys[:, 0]),
            _mask_pad_lanes(m, want, keys[:, 0]),
            err_msg=f"{mode} r={r}")


def test_spec_compose_lanes_rejects_unknown_mode():
    m = _matcher()
    maps, keys = _lane_runs(m, np.random.default_rng(81), [2, 2])
    with pytest.raises(ValueError, match="mode"):
        ops.spec_compose_lanes(maps, keys, m.dev.cidx_pad_j, m.dev.sinks_j,
                               pad_key=m.dev.pad_key, mode="bogus")


# --------------------------------------------------------------------------
# facade: lowering choice per backend + cross-backend bit-identity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("r", [1, 2])
def test_compose_lane_maps_lowerings_agree(r):
    rng = np.random.default_rng(82 + r)
    ms = {"local": _matcher("local", r), "pallas": _matcher("pallas", r),
          "sharded": _matcher("sharded", r, mesh_shape=(2, 4),
                              devices=8, num_chunks=4)}
    mt = _matcher("pallas", r)
    mt.executor.compose_mode = "tree"
    ms["pallas-tree"] = mt
    for lens in ([3, 3], [1, 6, 4], [5]):
        maps, keys = _lane_runs(ms["local"], rng, lens)
        outs = {name: _mask_pad_lanes(ms["local"],
                                      np.asarray(m.compose_lane_maps(
                                          maps, keys)), keys[:, 0])
                for name, m in ms.items()}
        for name, out in outs.items():
            np.testing.assert_array_equal(out, outs["local"],
                                          err_msg=f"{name} lens={lens}")
    assert ms["local"].perf_report()["compose_lowering"] == "compose-scan"
    assert ms["sharded"].perf_report()["compose_lowering"] == "compose-scan"
    assert (ms["pallas"].perf_report()["compose_lowering"]
            == "compose-kernel-carry")
    assert (ms["pallas-tree"].perf_report()["compose_lowering"]
            == "compose-kernel-tree")
    assert all(m.compose_calls > 0 for m in ms.values())


def test_ooo_pallas_tick_rides_compose_kernel():
    """The OOO gap-close fold itself (not just the API) rides the kernel."""
    from repro.streaming import OooPolicy, OooStreamMatcher

    rng = np.random.default_rng(83)
    m = _matcher("pallas")
    doc = bytes(rng.choice(ALPHABET, size=512).astype(np.uint8))
    want = m.membership_batch([doc])
    ooo = OooStreamMatcher(m, policy=OooPolicy(match_batch=4))
    s = ooo.open()
    segs = [doc[i * 64:(i + 1) * 64] for i in range(8)]
    for i in (3, 5, 7, 2, 6, 4, 1):  # arrive out of order, 0 last
        s.feed(i, segs[i], prev_tail=doc[i * 64 - 2:i * 64])
    s.feed(0, segs[0])
    ooo.flush()
    got = s.close()
    np.testing.assert_array_equal(got.final_states, want.final_states[0])
    assert m.compose_calls > 0
    rep = m.perf_report()
    assert str(rep["compose_lowering"]).startswith("compose-kernel"), rep


# --------------------------------------------------------------------------
# MeshLayout: Eq. 7 on the document axis
# --------------------------------------------------------------------------

def _mesh_layout(dd, dc, row_caps=None, width=64):
    rows = tuple(ChunkLayout.uniform(width, dc, dc) for _ in range(dd))
    rw = (tuple(capacity_weights(np.asarray(row_caps, np.float64)))
          if row_caps is not None else None)
    return MeshLayout(width, rows, row_weights=rw)


def test_doc_counts_sums_and_weighting():
    lay = _mesh_layout(4, 2, row_caps=[1, 1, 2, 2])
    for n in (0, 1, 7, 12, 100):
        counts = lay.doc_counts(n)
        assert counts.sum() == n and (counts >= 0).all()
    # fast rows get proportionally more documents
    counts = lay.doc_counts(12)
    assert counts[2] + counts[3] == 8 and counts[0] + counts[1] == 4
    # uniform layouts split evenly
    uni = _mesh_layout(4, 2)
    np.testing.assert_array_equal(uni.doc_counts(8), [2, 2, 2, 2])
    assert not uni.is_ragged and lay.is_ragged


def test_tile_rows_places_and_waterfills():
    lay = _mesh_layout(4, 2, row_caps=[1, 1, 2, 2])
    rowpos = lay.tile_rows(10, 16)  # rps = 4
    assert rowpos.shape == (10,) and len(set(rowpos.tolist())) == 10
    per_row = np.bincount(rowpos // 4, minlength=4)
    assert per_row.sum() == 10 and (per_row <= 4).all()
    # slow rows hold fewer real documents than fast rows
    assert per_row[:2].sum() < per_row[2:].sum()
    # a full tile cannot be ragged: every slot is real
    full = lay.tile_rows(16, 16)
    assert sorted(full.tolist()) == list(range(16))
    # uniform placement is exactly positional
    np.testing.assert_array_equal(_mesh_layout(4, 2).tile_rows(10, 16),
                                  np.arange(10))
    with pytest.raises(ValueError):
        lay.tile_rows(17, 16)    # m > tile
    with pytest.raises(ValueError):
        lay.tile_rows(4, 10)     # tile does not split over doc shards


# --------------------------------------------------------------------------
# ragged vs uniform doc placement: bit-identical end to end
# --------------------------------------------------------------------------

def _skewed_caps(dd, dc, rng):
    """Per-device capacities with deliberately skewed per-row aggregates."""
    row = rng.permutation(np.linspace(1.0, 2.5, dd))
    return np.repeat(row, dc) * rng.uniform(0.9, 1.1, dd * dc)


@pytest.mark.parametrize("mesh_shape", [(2, 4), (4, 2), (8, 1)])
def test_ragged_doc_layout_bit_identical(mesh_shape):
    dd, dc = mesh_shape
    rng = np.random.default_rng(84 + dd)
    dfas = [make_search_dfa(compile_regex(p)) for p in PATTERNS]
    kw = dict(num_chunks=max(2, dc), batch_tile=16, mesh_shape=mesh_shape,
              devices=8)
    uni = Matcher(dfas, backend="sharded", **kw)
    rag = Matcher(dfas, backend="sharded",
                  capacities=_skewed_caps(dd, dc, rng), **kw)
    assert (rag.planner.row_weights is not None) == (dd > 1)
    loc = Matcher(dfas, num_chunks=max(2, dc), batch_tile=16)
    # partial tiles (m < batch_tile) are where placement has slack; a
    # >1-tile batch covers the full-tile path too
    for m_docs in (5, 11, 16, 23):
        docs = [bytes(rng.choice(ALPHABET,
                                 size=int(rng.integers(10, 300)))
                      .astype(np.uint8)) for _ in range(m_docs)]
        want = loc.membership_batch(docs)
        for mm in (uni, rag):
            got = mm.membership_batch(docs)
            np.testing.assert_array_equal(got.final_states,
                                          want.final_states)
            np.testing.assert_array_equal(got.accepted, want.accepted)


def test_ragged_doc_layout_bit_identical_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    dfas = [make_search_dfa(compile_regex(p)) for p in PATTERNS[:2]]
    kw = dict(num_chunks=4, batch_tile=8, mesh_shape=(2, 4), devices=8)
    uni = Matcher(dfas, backend="sharded", **kw)
    rag = Matcher(dfas, backend="sharded",
                  capacities=[1.0, 1.1, 0.9, 1.0, 2.1, 1.9, 2.0, 2.2], **kw)

    @hyp.settings(max_examples=15, deadline=None)
    @hyp.given(docs=st.lists(st.binary(min_size=0, max_size=120),
                             min_size=1, max_size=7))
    def check(docs):
        got_u = uni.membership_batch(docs)
        got_r = rag.membership_batch(docs)
        np.testing.assert_array_equal(got_r.final_states,
                                      got_u.final_states)

    check()


# --------------------------------------------------------------------------
# observed-traffic autotuning
# --------------------------------------------------------------------------

def test_traffic_profile_snapshot_and_drift():
    p = TrafficProfile(max_samples=64)
    assert p.snapshot() is None
    for _ in range(10):
        p.record(4, np.full(4, 256))
    obs = p.snapshot()
    assert obs.batch == 4 and int(np.median(obs.lengths)) == 256
    assert p.n_tiles == 10 and p.n_docs == 40
    # drift is symmetric-ish log2 distance: 256 -> 2048 is 3 octaves
    far = ObservedTraffic(batch=4, lengths=(2048,) * 4)
    assert obs.drift(far) == pytest.approx(3.0, abs=0.1)
    assert obs.drift(obs) == pytest.approx(0.0, abs=1e-9)
    syn = synthetic_traffic()
    assert syn.batch == 8 and len(syn.lengths) == 8


def test_maybe_retune_requires_autotune():
    m = _matcher()
    with pytest.raises(ValueError, match="autotune"):
        m.maybe_retune()


@pytest.fixture
def fast_autotune(monkeypatch):
    """Real autotuner, deterministic clock: construction-time tunes (which
    would otherwise measure real probe workloads) take the injected
    ``time_fn`` path unless the caller supplies their own."""
    import repro.core.profiling as prof

    real = prof.autotune_spec_shapes

    def wrapped(packed, **kw):
        kw.setdefault("time_fn", lambda cfg: 1.0)
        if kw["time_fn"] is None:
            kw["time_fn"] = lambda cfg: 1.0
        return real(packed, **kw)

    monkeypatch.setattr(prof, "autotune_spec_shapes", wrapped)
    yield


def test_maybe_retune_applies_observed_shape(fast_autotune):
    clear_autotune_cache()
    m = _matcher("pallas", autotune=True)
    assert m.retunes == 0
    # no traffic yet: nothing to retune on
    assert not m.maybe_retune(time_fn=lambda c: 1.0)
    rng = np.random.default_rng(85)
    docs = [bytes(rng.choice(ALPHABET, size=4096).astype(np.uint8))
            for _ in range(8)]
    for _ in range(16):
        m.membership_batch(docs)
    assert m.traffic.n_docs >= 64
    obs = m.traffic_profile()
    assert obs is not None and int(np.median(obs.lengths)) == 4096
    # observed 4096-byte docs vs the 2048-byte synthetic probe: 1 octave,
    # below the default threshold -> gated; force ignores the gate
    assert not m.maybe_retune(drift_threshold=1.5, time_fn=lambda c: 1.0)

    def prefer_big_blocks(cfg):
        return {0: 10.0, 128: 5.0, 256: 3.0, 512: 1.0}.get(
            cfg.get("l_blk", 0), 10.0)

    assert m.maybe_retune(drift_threshold=0.5, time_fn=prefer_big_blocks)
    assert m.retunes == 1 and m.executor.spec_l_blk[0] == 512
    # spec-kernel lowerings were dropped so the tuned shape takes effect
    kinds = set(m.executor.lowering_kinds.values())
    assert not any(k.startswith("spec-kernel") for k in kinds)
    m.membership_batch(docs)  # recompiles at the tuned shape, bit-identical
    kinds = set(m.executor.lowering_kinds.values())
    assert any(k.startswith("spec-kernel") for k in kinds)
    # freshly re-tuned: the same traffic no longer drifts
    assert not m.maybe_retune(drift_threshold=0.5, time_fn=lambda c: 1.0)
    clear_autotune_cache()


def test_retune_keeps_results_bit_identical(fast_autotune):
    clear_autotune_cache()
    rng = np.random.default_rng(86)
    docs = [bytes(rng.choice(ALPHABET, size=int(n)).astype(np.uint8))
            for n in rng.integers(100, 2000, size=12)]
    m = _matcher("pallas", autotune=True)
    want = m.membership_batch(docs)
    for _ in range(8):
        m.membership_batch(docs)
    assert m.maybe_retune(force=True,
                          time_fn=lambda c: float(c.get("l_blk") or 64))
    got = m.membership_batch(docs)
    np.testing.assert_array_equal(got.final_states, want.final_states)
    assert m.perf_report()["retunes"] == 1
    assert m.perf_report()["traffic"]["n_docs"] >= 96
    clear_autotune_cache()
