"""Property-style invariants of the partitioning/planning layer.

Covers the paper's Eqs. 1–7 contract that the capacity-balanced runtime
relies on:

  * ``weighted_partition``/``uniform_partition`` sizes are a partition of n
    (non-negative, contiguous, sum to n) for any weights/m;
  * chunk 0 respects the multiple-of-m constraint (Eq. 2: the exact chunk is
    ~m x a speculative chunk under equal weights);
  * equal capacities with m = 1 degrade ``weighted_partition`` (and the
    planner's ``ChunkLayout.weighted``) to ``uniform_partition`` exactly;
  * ``capacity_weights`` is Eq. 1 (mean-normalized, rejects non-positive);
  * ``layout_device_work`` is conserved and proportional to capacities on
    full-width input.

Seeded random sweeps stand in for hypothesis (absent in the image); when
hypothesis is available the same properties also run fuzzed.
"""

import numpy as np
import pytest

from repro.core import (capacity_weights, profile_workers, synthetic_capacities,
                        uniform_partition, weighted_partition)
from repro.core.engine import ChunkLayout, Planner, layout_device_work

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - image has no hypothesis
    HAVE_HYPOTHESIS = False


def _check_is_partition(part, n):
    sizes = part.sizes
    assert (sizes >= 0).all()
    assert int(sizes.sum()) == n
    # contiguous, ordered spans covering [0, n)
    assert part.start[0] == 0 and part.end[-1] == n
    assert (part.start[1:] == part.end[:-1]).all()


def test_weighted_partition_is_a_partition_sweep():
    rng = np.random.default_rng(0)
    for trial in range(200):
        n = int(rng.integers(0, 50_000))
        p = int(rng.integers(1, 33))
        m = int(rng.integers(1, 65))
        w = capacity_weights(rng.uniform(0.25, 4.0, size=p))
        _check_is_partition(weighted_partition(n, w, m), n)
        _check_is_partition(uniform_partition(n, p, m), n)


def test_equal_capacities_m1_degrades_to_uniform_sweep():
    rng = np.random.default_rng(1)
    for trial in range(100):
        n = int(rng.integers(0, 50_000))
        p = int(rng.integers(1, 33))
        got = weighted_partition(n, np.ones(p), 1)
        want = uniform_partition(n, p, 1)
        np.testing.assert_array_equal(got.start, want.start)
        np.testing.assert_array_equal(got.end, want.end)


def test_equal_capacities_work_balanced_any_m():
    """Eqs. 2–7 with equal weights: per-processor scalar work (speculative
    chunks match m states) is balanced up to rounding."""
    rng = np.random.default_rng(2)
    for trial in range(50):
        p = int(rng.integers(2, 25))
        m = int(rng.integers(1, 33))
        n = int(rng.integers(64 * p * m, 128 * p * m))
        work = weighted_partition(n, np.ones(p), m).work()
        assert work.min() > 0
        assert float(work.max() / work.min()) < 1.1


def test_chunk0_multiple_of_m_constraint():
    """Eq. 2 under equal weights: the exact chunk 0 is ~m x a speculative
    chunk, so its one-state scan matches the m-state speculative lanes."""
    rng = np.random.default_rng(3)
    n = 200_000
    for trial in range(50):
        p = int(rng.integers(2, 25))
        m = int(rng.integers(1, 33))
        part = weighted_partition(n, np.ones(p), m)
        spec = part.sizes[1:]
        assert spec.min() > 0
        ratio = part.sizes[0] / spec.astype(np.float64).mean()
        assert ratio == pytest.approx(m, rel=0.1)


def test_capacity_weights_eq1():
    w = capacity_weights(np.array([2.0, 1.0, 1.0]))
    assert w.mean() == pytest.approx(1.0)
    assert w[0] == pytest.approx(2.0 * 3 / 4.0)
    np.testing.assert_allclose(profile_workers([3.0, 1.0]), [1.5, 0.5])
    with pytest.raises(ValueError):
        capacity_weights(np.array([1.0, 0.0]))
    with pytest.raises(ValueError):
        capacity_weights(np.array([-1.0, 2.0]))


def test_layout_device_work_conserved_sweep():
    rng = np.random.default_rng(4)
    for trial in range(100):
        d = int(rng.integers(1, 9))
        cpd = int(rng.integers(1, 5))
        lc = int(rng.integers(1, 257))
        c = d * cpd
        width = c * lc
        caps = rng.uniform(0.5, 2.0, size=d)
        layout = ChunkLayout.weighted(width, c, d, capacity_weights(caps))
        assert layout.num_chunks == c and layout.num_devices == d
        lengths = rng.integers(0, width + 1, size=7)
        work = layout_device_work(layout, lengths)
        assert work.shape == (d,)
        assert int(work.sum()) == int(lengths.sum())  # every symbol assigned
        # equal capacities degrade the layout to uniform exactly
        uni = ChunkLayout.weighted(width, c, d, np.ones(d))
        ref = ChunkLayout.uniform(width, c, d)
        np.testing.assert_array_equal(uni.starts, ref.starts)
        np.testing.assert_array_equal(uni.ends, ref.ends)


def test_weighted_layout_proportional_to_capacity():
    """Full-width input: per-device work tracks the skewed capacity profile
    (the load-balancing mechanism the sharded executor inherits)."""
    d, cpd, width = 8, 2, 65_536
    caps = synthetic_capacities(d)  # 1.41x fast half
    layout = ChunkLayout.weighted(width, d * cpd, d, profile_workers(caps))
    work = layout_device_work(layout, np.array([width]))
    util = work / caps
    assert float(util.max() / util.mean()) < 1.02
    # uniform layout on the same profile leaves the paper's 1.41 skew
    uni = ChunkLayout.uniform(width, d * cpd, d)
    uutil = layout_device_work(uni, np.array([width])) / caps
    assert float(uutil.max() / uutil.mean()) > 1.15


def test_planner_rounds_chunks_and_validates():
    pl = Planner(num_chunks=6, devices=4)
    assert pl.num_chunks == 8  # rounded up to a device multiple
    with pytest.raises(ValueError):
        Planner(num_chunks=0)
    with pytest.raises(ValueError):
        Planner(num_chunks=8, max_buckets=0)
    with pytest.raises(ValueError):
        Planner(num_chunks=8, devices=2, weights=np.ones(3))


def test_planner_bucket_plan_matches_sticky_policy():
    pl = Planner(num_chunks=8, max_buckets=2)
    lengths = np.array([0, 3, 31, 32, 100, 255, 513, 1024, 2000])
    plan = pl.plan(lengths)
    # short docs (< 4 * C = 32) are sequential
    np.testing.assert_array_equal(plan.spec_mask, lengths >= 32)
    kinds = [b.kind for b in plan.buckets]
    assert kinds.count("seq") == 1
    assert 1 <= kinds.count("spec") <= 2
    assert len(pl.spec_keys) <= 2
    covered = np.concatenate([b.doc_idx for b in plan.buckets])
    assert sorted(covered.tolist()) == list(range(len(lengths)))
    # sticky: a second batch inside the compiled range adds no keys
    keys = list(pl.spec_keys)
    pl.plan(np.array([40, 700, 1800]))
    assert pl.spec_keys == keys


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(n=st.integers(0, 50_000), p=st.integers(1, 32),
           m=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
    def test_weighted_partition_is_a_partition_fuzzed(n, p, m, seed):
        rng = np.random.default_rng(seed)
        w = capacity_weights(rng.uniform(0.25, 4.0, size=p))
        _check_is_partition(weighted_partition(n, w, m), n)
