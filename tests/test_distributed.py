"""Multi-device distributed tests.

Each test spawns a subprocess with XLA_FLAGS forcing 8 host devices (the main
pytest process must keep seeing 1 device for the smoke tests), builds a small
(pod, data, model) mesh, and checks the distributed path against the local
reference.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(body: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", "")).strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                          capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_hierarchical_merge_matches_host_fold():
    run_in_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.distributed.collectives import (hierarchical_merge_lvecs,
                                                   flat_merge_lvecs)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rng = np.random.default_rng(0)
        q, c = 33, 16
        maps = rng.integers(0, q, size=(c, q)).astype(np.int32)
        want = np.arange(q, dtype=np.int32)
        for i in range(c):
            want = maps[i][want]
        got_h = np.asarray(hierarchical_merge_lvecs(jnp.asarray(maps), mesh))
        got_f = np.asarray(flat_merge_lvecs(jnp.asarray(maps), mesh))
        np.testing.assert_array_equal(got_h, want)
        np.testing.assert_array_equal(got_f, want)
        print("merge OK")
    """)


def test_distributed_membership_matches_sequential():
    run_in_subprocess("""
        import numpy as np, jax
        from repro.core import random_dfa
        from repro.distributed.collectives import distributed_membership
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rng = np.random.default_rng(7)
        dfa = random_dfa(29, 6, rng=rng)
        classes = rng.integers(0, 6, size=10_007).astype(np.int32)
        want = dfa.start
        for cl in classes:
            want = int(dfa.table[want, cl])
        got = distributed_membership(dfa.table, classes, dfa.start, dfa.sink,
                                     dfa.accepting, mesh)
        assert got == want, (got, want)
        print("distributed membership OK")
    """)


def test_moe_sharded_matches_local():
    run_in_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.models.moe import init_moe, moe_mlp
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        key = jax.random.PRNGKey(0)
        d, ff, e, topk = 32, 64, 4, 2
        p = init_moe(key, d, ff, e)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, d), jnp.bfloat16)
        out_local, aux_l = moe_mlp(p, x, top_k=topk, mesh=None)
        out_shard, aux_s = moe_mlp(p, x, top_k=topk, mesh=mesh)
        # sharded path splits tokens into smaller dispatch groups; routing is
        # identical, capacity boundaries differ -> allow small mismatch count
        a = np.asarray(out_local, np.float32)
        b = np.asarray(out_shard, np.float32)
        mismatch = np.mean(~np.isclose(a, b, atol=3e-2))
        assert mismatch < 0.05, mismatch
        assert np.isfinite(float(aux_s))
        print("moe OK", mismatch)
    """)


def test_pipeline_matches_sequential_stages():
    run_in_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.distributed.pipeline import pipeline_apply
        mesh = jax.make_mesh((4,), ("stage",))
        s, m, d = 4, 6, 16
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (s, d, d), jnp.float32) * 0.3
        xs = jax.random.normal(jax.random.PRNGKey(1), (m, 2, d), jnp.float32)
        def stage_fn(w, x):
            return jnp.tanh(x @ w)
        got = np.asarray(pipeline_apply(stage_fn, ws, xs, mesh))
        want = np.asarray(xs)
        for i in range(s):
            want = np.tanh(want @ np.asarray(ws[i]))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
        print("pipeline OK")
    """)


def test_compressed_pod_mean_error_feedback():
    run_in_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.distributed.compression import (compressed_pod_mean,
                                                   init_error_state)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 8))
                              .astype(np.float32))}
        e = init_error_state(g)
        mean, e2 = compressed_pod_mean(g, e, mesh)
        # replicated grads -> mean == dequant(quant(g)); error = residual
        np.testing.assert_allclose(np.asarray(mean["w"]), np.asarray(g["w"]),
                                   atol=np.abs(np.asarray(g['w'])).max()/100)
        resid = np.asarray(e2["w"])
        assert np.abs(resid).max() <= np.abs(np.asarray(g["w"])).max() / 127 + 1e-6
        # error feedback: corrected quantity g+e is preserved across rounds
        mean2, e3 = compressed_pod_mean(g, e2, mesh)
        total = np.asarray(mean2["w"]) + np.asarray(e3["w"])
        np.testing.assert_allclose(total, np.asarray(g["w"]) + resid, atol=1e-5)
        print("compression OK")
    """)


def test_train_step_on_small_production_mesh():
    """Full sharded train step (FSDP+TP+EP) on a (2,2,2) mesh, MoE arch."""
    run_in_subprocess("""
        import numpy as np, jax
        from repro.jax_compat import set_mesh
        from repro.configs import ShapeSpec, get_config, reduce_for_smoke
        from repro.models import api
        from repro.training.train_loop import (TrainOptions,
                                               init_train_state_sharded,
                                               jit_train_step)
        from repro.distributed import sharding as shr
        import jax.numpy as jnp

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = reduce_for_smoke(get_config("granite-moe-1b-a400m"))
        shape = ShapeSpec("t", "train", 64, 8)
        batch = api.make_inputs(cfg, shape, seed=0)
        opts = TrainOptions(num_microbatches=2, grad_compression="int8")
        with set_mesh(mesh):
            state = init_train_state_sharded(cfg, jax.random.PRNGKey(0), mesh, opts)
            bspecs = shr.batch_specs(batch, mesh, 8)
            step = jit_train_step(cfg, mesh, state, bspecs, opts)
            state2, metrics = step(state, batch)
            loss1 = float(metrics["loss"])
            state3, metrics = step(state2, batch)
            loss2 = float(metrics["loss"])
        assert np.isfinite(loss1) and np.isfinite(loss2)
        assert loss2 < loss1 + 0.5
        print("sharded train step OK", loss1, loss2)
    """)


def test_elastic_reshard_across_meshes():
    """Save on a (2,2,2)=8-device mesh, restore on (2,2)=4 devices."""
    run_in_subprocess("""
        import tempfile, numpy as np, jax
        from repro.configs import ShapeSpec, get_config, reduce_for_smoke
        from repro.models import api
        from repro.training import CheckpointManager, init_train_state
        from repro.training.train_loop import state_shardings
        from repro.distributed import sharding as shr

        cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
        mesh_a = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        state = jax.device_put(state, state_shardings(state, mesh_a))
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, use_async=False)
            mgr.save(state, 5)
            mesh_b = jax.make_mesh((2, 2), ("data", "model"),
                                   devices=jax.devices()[:4])
            like = jax.tree.map(lambda x: np.asarray(x), state)
            shard_b = state_shardings(state, mesh_b)
            restored, step = mgr.restore(like, shardings=shard_b)
        assert step == 5
        leaf = jax.tree.leaves(restored)[0]
        assert len(leaf.sharding.device_set) <= 4
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("elastic reshard OK")
    """)
