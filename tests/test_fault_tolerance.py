"""Fault-tolerance layer: checkpoint/restore, retry-with-restore, rebalance.

The acceptance bar (ISSUE 6): a stream checkpointed mid-segment and restored
on a *different* mesh shape yields final [B, K] results bit-identical to the
uninterrupted run, and an injected-fault scheduler run (killed ticks,
degraded capacities) completes with zero lost and zero double-composed
segments.  Byte counts are the loss/double-compose detector: a lost segment
deflates ``byte_count`` below the fed total, a double-composed one inflates
it — so ``byte_count == len(doc)`` plus bit-identical finals is exact.
"""

import os

import numpy as np
import pytest

import jax

from repro.core import Matcher, compile_regex, make_search_dfa
from repro.launch.mesh import make_matcher_mesh
from repro.streaming import (FaultPlan, InjectedFault, RetryPolicy,
                             StreamMatcher, TickPolicy, table_signature)

PATTERNS = [".*(ab|ba){2}", ".*[0-9]{3}", ".*x+y"]
ALPHABET = np.frombuffer(b"abxy0189", np.uint8)
LAZY = TickPolicy(max_batch=1 << 30, max_delay=1 << 30)  # explicit flush


def _dfas():
    return [make_search_dfa(compile_regex(p)) for p in PATTERNS]


def _docs(rng, n, size):
    return [bytes(rng.choice(ALPHABET, size=size).astype(np.uint8))
            for _ in range(n)]


def _oracle(dfas, docs):
    return Matcher(dfas, num_chunks=1).membership_batch(docs).final_states


def _mesh_or_skip(shape):
    if len(jax.devices()) < shape[0] * shape[1]:
        pytest.skip(f"needs {shape[0] * shape[1]} host devices")
    return make_matcher_mesh(shape=shape)


def _run_segments(sm, docs, seg, *, swallow=()):
    sessions = [sm.open() for _ in docs]
    rounds = max(-(-len(d) // seg) for d in docs)
    for r in range(rounds):
        for s, d in zip(sessions, docs):
            piece = d[r * seg:(r + 1) * seg]
            if piece:
                try:
                    s.feed(piece)
                except swallow:
                    pass
        try:
            sm.flush()
        except swallow:
            pass
    while True:
        try:
            sm.flush()
            break
        except swallow:
            continue
    return sessions


def _check(sessions, docs, oracle):
    finals = np.stack([s.close().final_states for s in sessions])
    assert (finals == oracle).all()
    for s, d in zip(sessions, docs):
        assert s.byte_count == len(d)  # no loss, no double-compose


# --------------------------------------------------------------------------
# satellite: empty feeds are no-ops that still advance deadlines
# --------------------------------------------------------------------------

def test_empty_feed_is_noop():
    sm = StreamMatcher(_dfas())
    s = sm.open()
    s.feed(b"")  # eager policy + empty queue: nothing to dispatch
    assert sm.stats.ticks == 0 and sm.stats.empty_feeds == 1
    assert sm.scheduler.pending_streams == 0
    r = s.close()
    assert r.byte_count == 0 and r.segments_fed == 1


def test_empty_feed_advances_max_delay_deadline():
    sm = StreamMatcher(_dfas(), policy=TickPolicy(max_batch=64, max_delay=2))
    a, b = sm.open(), sm.open()
    a.feed(b"ab")      # event 1: a pending since seq 1
    b.feed(b"")        # event 2: waited 1 < 2 -> no tick
    assert sm.stats.ticks == 0
    b.feed(b"")        # event 3: a waited 2 >= 2 -> tick fires
    assert sm.stats.ticks == 1
    assert a.byte_count == 2
    assert sm.stats.empty_feeds == 2


def test_empty_feed_never_occupies_a_queue_slot():
    sm = StreamMatcher(_dfas(), policy=TickPolicy(max_batch=3, max_delay=0,
                                                  max_delay_s=None))
    sessions = [sm.open() for _ in range(3)]
    sessions[0].feed(b"")
    sessions[1].feed(b"")
    # two empty feeds must not count toward max_batch=3
    assert sm.scheduler.pending_streams == 0 and sm.stats.ticks == 0


# --------------------------------------------------------------------------
# tentpole (3): retry-with-restore — killed ticks, no loss, no double-compose
# --------------------------------------------------------------------------

def test_injected_prefault_retries_bit_identical():
    rng = np.random.default_rng(0)
    dfas = _dfas()
    docs = _docs(rng, 6, 96)
    oracle = _oracle(dfas, docs)
    plan = FaultPlan(kill={0: 2, 1: 1})
    sm = StreamMatcher(dfas, retry=RetryPolicy(max_retries=3),
                       fault_plan=plan)
    sessions = _run_segments(sm, docs, 32)
    _check(sessions, docs, oracle)
    assert plan.injected == 3
    assert sm.stats.retries == 3
    assert sm.stats.dispatch_failures == 3
    assert sm.stats.failed_ticks == 0


def test_injected_postfault_does_not_double_compose():
    # the nasty case: the fault fires *after* cursors were committed — the
    # retry must roll them back or every segment composes twice
    rng = np.random.default_rng(1)
    dfas = _dfas()
    docs = _docs(rng, 5, 64)
    oracle = _oracle(dfas, docs)
    plan = FaultPlan(kill_post={0: 1, 1: 1})
    sm = StreamMatcher(dfas, retry=RetryPolicy(max_retries=2),
                       fault_plan=plan)
    sessions = _run_segments(sm, docs, 32)
    _check(sessions, docs, oracle)
    assert plan.injected == 2 and sm.stats.retries == 2


def test_giveup_requeues_and_later_flush_completes():
    rng = np.random.default_rng(2)
    dfas = _dfas()
    docs = _docs(rng, 4, 64)
    oracle = _oracle(dfas, docs)
    plan = FaultPlan(kill={0: 5})  # outlasts max_retries=1 -> give up once
    sm = StreamMatcher(dfas, policy=LAZY, retry=RetryPolicy(max_retries=1),
                       fault_plan=plan)
    sessions = [sm.open() for _ in docs]
    for s, d in zip(sessions, docs):
        s.feed(d[:32])
    with pytest.raises(InjectedFault):
        sm.flush()
    # nothing lost: the failed tick returned every segment to admission
    assert sm.stats.failed_ticks == 1
    assert sm.stats.requeued_segments == len(docs)
    assert all(s.pending_bytes == 32 for s in sessions)
    for s, d in zip(sessions, docs):
        s.feed(d[32:])
    sm.flush()  # tick index moved past the kill schedule -> succeeds
    _check(sessions, docs, oracle)


def test_retry_backoff_uses_injected_sleep():
    sleeps = []
    plan = FaultPlan(kill={0: 2})
    sm = StreamMatcher(_dfas(),
                       retry=RetryPolicy(max_retries=3, backoff_s=0.125,
                                         backoff_factor=2.0, max_backoff_s=1.0))
    sm.scheduler.fault_plan = plan
    sm.scheduler._sleep = sleeps.append
    s = sm.open()
    s.feed(b"abab")
    assert sleeps == [0.125, 0.25]
    assert s.byte_count == 4


def test_retry_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_factor=0.5)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_s=-1.0)
    assert RetryPolicy(backoff_s=0.5, max_backoff_s=0.8).delay(3) == 0.8


def test_fault_plan_phase_validation():
    with pytest.raises(ValueError):
        FaultPlan().maybe_fail(0, 0, "mid")


# --------------------------------------------------------------------------
# tentpole (1): snapshot/restore, including across mesh shapes
# --------------------------------------------------------------------------

def test_snapshot_restore_roundtrip_local(tmp_path):
    rng = np.random.default_rng(3)
    dfas = _dfas()
    docs = _docs(rng, 5, 48)
    oracle = _oracle(dfas, docs)
    sm = StreamMatcher(dfas, policy=LAZY)
    sessions = [sm.open() for _ in docs]
    for s, d in zip(sessions, docs):
        s.feed(d[:16])
    sm.flush()
    for s, d in zip(sessions, docs):
        s.feed(d[16:32])  # pending at snapshot time
    sm.snapshot(str(tmp_path))

    sm2 = StreamMatcher(dfas, policy=LAZY)
    restored = {s.sid: s for s in sm2.restore(str(tmp_path))}
    sessions2 = [restored[s.sid] for s in sessions]
    assert all(s.pending_bytes == 16 for s in sessions2)
    for s, d in zip(sessions2, docs):
        s.feed(d[32:])
    sm2.flush()
    _check(sessions2, docs, oracle)
    # segments_fed carried over: 2 before the snapshot + 1 after
    assert all(s.segments_fed == 3 for s in sessions2)


@pytest.mark.parametrize("src_shape,dst_shape", [
    ((2, 4), (1, 1)),
    ((2, 4), (8, 1)),
    ((1, 1), (2, 4)),
])
def test_snapshot_restore_across_mesh_shapes(tmp_path, src_shape, dst_shape):
    src_mesh = _mesh_or_skip(src_shape)
    dst_mesh = _mesh_or_skip(dst_shape)
    rng = np.random.default_rng(4)
    dfas = _dfas()
    docs = _docs(rng, 4, 128)
    oracle = _oracle(dfas, docs)

    sm = StreamMatcher(dfas, backend="sharded", mesh=src_mesh, num_chunks=8,
                       policy=LAZY)
    sessions = [sm.open() for _ in docs]
    for s, d in zip(sessions, docs):
        s.feed(d[:64])
    sm.flush()
    for s, d in zip(sessions, docs):
        s.feed(d[64:96])  # in-flight pending bytes cross the mesh change
    sm.snapshot(str(tmp_path))

    sm2 = StreamMatcher(dfas, backend="sharded", mesh=dst_mesh, num_chunks=8,
                        policy=LAZY)
    restored = {s.sid: s for s in sm2.restore(str(tmp_path))}
    sessions2 = [restored[s.sid] for s in sessions]
    for s, d in zip(sessions2, docs):
        s.feed(d[96:])
    sm2.flush()
    _check(sessions2, docs, oracle)


def test_restore_ignores_crashed_writer_tmp(tmp_path):
    sm = StreamMatcher(_dfas(), policy=LAZY)
    s = sm.open()
    s.feed(b"ba")
    sm.snapshot(str(tmp_path))
    # a writer that died mid-publish leaves step_<N>.tmp; restore skips it
    os.makedirs(tmp_path / "step_00000099.tmp")
    (tmp_path / "step_00000099.tmp" / "arrays.npz").write_bytes(b"garbage")
    os.makedirs(tmp_path / "step_junk")  # stray non-numeric dir tolerated

    sm2 = StreamMatcher(_dfas(), policy=LAZY)
    restored = sm2.restore(str(tmp_path))
    assert len(restored) == 1 and restored[0].pending_bytes == 2
    r = restored[0].close()
    assert r.byte_count == 2


def test_restore_refuses_wrong_pattern_set(tmp_path):
    sm = StreamMatcher(_dfas(), policy=LAZY)
    sm.open().feed(b"ab")
    sm.snapshot(str(tmp_path))
    other = StreamMatcher([make_search_dfa(compile_regex(".*zz"))],
                          policy=LAZY)
    with pytest.raises(ValueError, match="different packed pattern set"):
        other.restore(str(tmp_path))


def test_restore_refuses_sid_collision(tmp_path):
    sm = StreamMatcher(_dfas(), policy=LAZY)
    sm.open().feed(b"ab")
    sm.snapshot(str(tmp_path))
    sm2 = StreamMatcher(_dfas(), policy=LAZY)
    sm2.open()  # sid 0 already open here
    with pytest.raises(ValueError, match="already open"):
        sm2.restore(str(tmp_path))


def test_restore_continues_sid_allocation(tmp_path):
    sm = StreamMatcher(_dfas(), policy=LAZY)
    for _ in range(3):
        sm.open()
    sm.snapshot(str(tmp_path))
    sm2 = StreamMatcher(_dfas(), policy=LAZY)
    sm2.restore(str(tmp_path))
    assert sm2.open().sid == 3  # never re-issues a restored sid


def test_table_signature_distinguishes_pattern_sets():
    a = Matcher(_dfas()).packed
    b = Matcher([make_search_dfa(compile_regex(".*zz"))]).packed
    assert table_signature(a) == table_signature(a)
    assert table_signature(a) != table_signature(b)


# --------------------------------------------------------------------------
# satellite: training/checkpoint reshard round-trips + tolerant step parse
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 1), (2, 4), (8, 1)])
def test_checkpoint_reshard_roundtrip_mesh_shapes(tmp_path, shape):
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.training.checkpoint import restore_checkpoint, save_checkpoint
    mesh = _mesh_or_skip(shape)
    tree = {"a": np.arange(24, dtype=np.int32).reshape(4, 6),
            "b": np.linspace(0.0, 1.0, 7, dtype=np.float32)}
    save_checkpoint(str(tmp_path), tree, 5)
    repl = NamedSharding(mesh, PartitionSpec())
    out, step = restore_checkpoint(
        str(tmp_path), {k: np.zeros(0) for k in tree},
        shardings={k: repl for k in tree})
    assert step == 5
    for k in tree:
        assert (np.asarray(out[k]) == tree[k]).all()


def test_latest_step_tolerates_stray_entries(tmp_path):
    from repro.training.checkpoint import latest_step, save_checkpoint
    assert latest_step(str(tmp_path)) is None
    save_checkpoint(str(tmp_path), {"x": np.zeros(2)}, 3)
    os.makedirs(tmp_path / "step_00000009.tmp")   # crashed writer
    os.makedirs(tmp_path / "step_notanumber")     # stray dir
    (tmp_path / "step_8").mkdir()                 # unpadded but numeric
    assert latest_step(str(tmp_path)) == 8


# --------------------------------------------------------------------------
# tentpole (2): degraded-capacity rebalancing between ticks
# --------------------------------------------------------------------------

def test_straggler_capacities():
    from repro.distributed.fault_tolerance import StragglerPolicy
    p = StragglerPolicy(n_workers=4)
    with pytest.raises(ValueError):
        p.capacities()
    p.update(np.array([1.0, 1.0, 1.0, 2.0]))
    caps = p.capacities()
    assert caps.shape == (4,) and caps[3] < caps[0]


def test_rebalance_bit_identity_and_lowering_cache_survival():
    mesh = _mesh_or_skip((1, 2))
    rng = np.random.default_rng(5)
    dfas = _dfas()
    m = Matcher(dfas, backend="sharded", mesh=mesh, num_chunks=4)
    docs = _docs(rng, 4, 64) + _docs(rng, 2, 8)  # spec + seq buckets
    before = m.membership_batch(docs)
    keys_before = set(m.executor._lowered)
    traces_before = m.executor.traces

    m.rebalance([2.0, 1.0])
    assert m.planner.weights is not None
    after = m.membership_batch(docs)
    assert (after.final_states == before.final_states).all()
    # layout moved real symbols toward the faster device
    assert after.device_work[0] > before.device_work[0]

    # spec programs re-lowered under the new layout epoch; every old entry
    # (notably the layout-independent seq program) survived the rebalance
    assert keys_before <= set(m.executor._lowered)
    spec_traces = m.executor.traces - traces_before
    assert spec_traces >= 1

    # a third run recompiles nothing
    traces = m.executor.traces
    again = m.membership_batch(docs)
    assert m.executor.traces == traces
    assert (again.final_states == before.final_states).all()


def test_rebalance_validates():
    mesh = _mesh_or_skip((1, 2))
    m = Matcher(_dfas(), backend="sharded", mesh=mesh, num_chunks=4)
    with pytest.raises(ValueError):
        m.rebalance([1.0])          # wrong arity
    with pytest.raises(ValueError):
        m.rebalance([1.0, 0.0])     # non-positive
    m_local = Matcher(_dfas())
    with pytest.raises(ValueError):
        m_local.rebalance([1.0])    # sharded-only


def test_scheduler_straggler_rebalances_between_ticks():
    from repro.distributed.fault_tolerance import StragglerPolicy
    mesh = _mesh_or_skip((1, 2))
    rng = np.random.default_rng(6)
    dfas = _dfas()
    docs = _docs(rng, 4, 96)
    oracle = _oracle(dfas, docs)
    # multiplicative skew: device 0 reports 8x slower regardless of the
    # absolute tick wall time (robust on loaded CI hosts); enough ticks for
    # the EWMA to decay tick 0's one-off compile wall
    skew = np.array([8.0, 1.0])
    plan = FaultPlan(capacity_skew={t: skew for t in range(1, 128)})
    sm = StreamMatcher(dfas, backend="sharded", mesh=mesh, num_chunks=4,
                       straggler=StragglerPolicy(n_workers=2),
                       fault_plan=plan)
    sessions = _run_segments(sm, docs, 8)
    assert sm.stats.rebalances >= 1
    _check(sessions, docs, oracle)


# --------------------------------------------------------------------------
# satellite: calibration cache + explicit recalibrate
# --------------------------------------------------------------------------

def test_calibration_cached_per_device_set(monkeypatch):
    from repro.core import profiling
    profiling.clear_calibration_cache()
    calls = {"n": 0}

    def fake_profile(dfa=None, *, n_symbols, repeats, seed=0, devices):
        calls["n"] += 1
        return np.ones(len(devices))

    monkeypatch.setattr(profiling, "profile_capacity", fake_profile)
    mesh = _mesh_or_skip((1, 2))
    dfas = _dfas()
    m1 = Matcher(dfas, backend="sharded", mesh=mesh, calibrate=True)
    m2 = Matcher(dfas, backend="sharded", mesh=mesh, calibrate=True)
    assert calls["n"] == 1  # second construction hits the cache
    assert m1.capacities is not None and m2.capacities is not None

    caps = m1.recalibrate()  # explicit refresh owned by the rebalance path
    assert calls["n"] == 2
    assert caps.shape == (2,)
    profiling.clear_calibration_cache()


def test_calibrated_capacities_returns_copies(monkeypatch):
    from repro.core import profiling
    profiling.clear_calibration_cache()
    monkeypatch.setattr(
        profiling, "profile_capacity",
        lambda dfa=None, *, n_symbols, repeats, seed=0, devices:
            np.ones(len(devices)))
    caps = profiling.calibrated_capacities(jax.devices()[:1])
    caps[0] = 99.0
    assert profiling.calibrated_capacities(jax.devices()[:1])[0] == 1.0
    profiling.clear_calibration_cache()


# --------------------------------------------------------------------------
# regression (ISSUE 9): checkpoint identity must cover the *full* pattern
# set — a hot-swapped table, a swapped sibling block, or a changed prefilter
# literal table each invalidate every tree of the snapshot
# --------------------------------------------------------------------------


def test_restore_refused_after_hot_swap(tmp_path):
    from repro.core import compile_regex, make_search_dfa

    sm = StreamMatcher(_dfas(), policy=LAZY)
    s = sm.open()
    s.feed(b"abba")
    sm.flush()
    sm.snapshot(str(tmp_path))
    assert sm.swap_patterns(
        [make_search_dfa(compile_regex(".*zz[0-9]+"))]) is True
    with pytest.raises(ValueError, match="different packed pattern set"):
        sm.restore(str(tmp_path))


def test_blocked_restore_refused_after_sibling_block_swap(tmp_path):
    """The pre-fix hole: per-block table signatures alone would accept a
    snapshot whose *other* blocks were swapped.  The full-set signature
    stamped over every block's tree must refuse it."""
    from repro.core import PatternSet
    from repro.streaming import BlockedStreamMatcher

    ps = PatternSet({"a": "ab+", "b": "[0-9]x", "c": "yy", "d": "x+y"},
                    k_blk=2, search=True)
    sm = BlockedStreamMatcher(ps, policy=LAZY, num_chunks=4)
    s = sm.open()
    s.feed(b"abb 3x")
    sm.flush()
    sm.snapshot(str(tmp_path))
    # swap only block 1; block 0's own table bytes are untouched...
    info = sm.swap_patterns(ps.with_patterns({"d": "qq+"}))
    assert info["reused"] == [0] and info["rebuilt"] == [1]
    # ...yet restoring block 0's tree must refuse too: its signature covers
    # the whole set, and the in-flight swap changed a sibling block
    fresh = BlockedStreamMatcher(sm.blocked, policy=LAZY)
    with pytest.raises(ValueError, match="different packed pattern set"):
        fresh.restore(str(tmp_path))
    # a runtime still on the original set restores and resumes
    back = BlockedStreamMatcher(ps, policy=LAZY, num_chunks=4)
    (sess,) = back.restore(str(tmp_path))
    sess.feed(b"y")
    res = sess.close()
    assert res.byte_count == 7
    assert res.accepted.tolist() == [True, True, False, True]


def test_blocked_snapshot_covers_prefilter_tables(tmp_path):
    """Same tables, different prefilter config -> different identity."""
    from repro.core import PatternSet
    from repro.streaming import BlockedStreamMatcher

    ps = PatternSet({"a": "abc", "b": "def"}, k_blk=1, search=True)
    sm_on = BlockedStreamMatcher(ps, policy=LAZY, prefilter=True)
    sm_off = BlockedStreamMatcher(ps, policy=LAZY, prefilter=False)
    s = sm_on.open()
    s.feed(b"ab")
    sm_on.flush()
    sm_on.snapshot(str(tmp_path))
    with pytest.raises(ValueError, match="different packed pattern set"):
        sm_off.restore(str(tmp_path))
