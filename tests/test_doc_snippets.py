"""Documentation code must run: execute the README's ```python blocks.

The CI docs job runs the same snippets via tools/run_doc_snippets.py (plus
the examples); keeping a tier-1 copy means a doc-rotting change fails plain
``pytest -x -q`` locally too, before any PR is opened.
"""

import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

from run_doc_snippets import python_blocks, run_file  # noqa: E402


def test_readme_exists_with_runnable_quickstart():
    readme = ROOT / "README.md"
    assert readme.exists(), "top-level README.md is part of the public API"
    blocks = python_blocks(readme.read_text())
    assert blocks, "README must carry at least one runnable python snippet"
    # the quickstart exercises both entry points
    joined = "\n".join(blocks)
    assert "Matcher(" in joined and "StreamMatcher(" in joined


def test_readme_snippets_execute():
    assert run_file(ROOT / "README.md") >= 1


def test_architecture_doc_exists_and_is_linked():
    arch = ROOT / "docs" / "architecture.md"
    assert arch.exists()
    text = arch.read_text()
    for anchor in ("Adding an executor backend", "doc", "chunk",
                   "all_gather"):
        assert anchor in text
    assert "docs/architecture.md" in (ROOT / "README.md").read_text(), \
        "README must link the architecture doc"
    # any python blocks in the architecture doc must run too
    if python_blocks(text):
        run_file(arch)


@pytest.mark.parametrize("name", ["quickstart.py", "corpus_filter.py"])
def test_fast_examples_smoke(name):
    """The two cheap examples run end to end (CI also runs the heavy ones)."""
    import subprocess
    env = {"PYTHONPATH": str(ROOT / "src")}
    import os
    env = {**os.environ, **env}
    proc = subprocess.run([sys.executable, str(ROOT / "examples" / name)],
                          capture_output=True, text=True, timeout=600,
                          env=env, cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-2000:]
