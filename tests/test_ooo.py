"""Out-of-order ingestion tier: match-first / sequence-later invariants.

The tier's whole contract is ONE sentence: for any arrival permutation,
any duplicate deliveries, and any segmentation, a closed stream's decision
is bit-identical to feeding the same bytes in order — on every backend and
mesh shape, with zero host-side compositions on the data path and the gap
close folding each contiguous buffered run through a single
``lax.associative_scan`` dispatch.  These tests pin each clause:

  * the scan-compose primitive against its sequential numpy reference
    (``kernels.ref.spec_merge_lanes_scan_ref``) and against whole-document
    matching (seeded sweep + hypothesis property when installed);
  * permutation/duplicate bit-identity across local / pallas / sharded
    backends and 1x1 / 2x4 / 8x1 meshes, ``merge_calls()`` flat;
  * single-dispatch gap close (``OooStats.scan_folds``), dedup, integrity
    conflicts, backpressure, bounded buffers, zero-byte segments;
  * failover: snapshot mid-reorder (parked payloads AND matched maps)
    restores bit-identically, including across mesh shapes;
  * the scheduler twin: ``StreamMatcher(lane_ticks=True)`` +
    ``open_at``/``close_map`` composes candidate-keyed sessions across
    ticks against the pure host reference.
"""

import os
import random

import numpy as np
import pytest

import jax

from repro.core import Matcher, compile_regex, make_search_dfa
from repro.core.lvector import merge_scan_lanes_jnp
from repro.kernels import ref as kref
from repro.launch.mesh import make_matcher_mesh
from repro.streaming import (OooPolicy, OooStreamMatcher, SequenceGapError,
                             StreamMatcher, merge, merge_calls,
                             open_lane_cursor, segment_result)
from repro.streaming.ooo import (FP_MOD, OooIntegrityError, ReorderBufferFull,
                                 compose_fingerprints, segment_fingerprint)
from repro.streaming.ooo.checkpoint import OOO_TREE_KEYS, ooo_tree

PATTERNS = [".*(ab|ba){2}", ".*[0-9]{3}", ".*x+y"]
ALPHABET = list(b"abxy0189")

BACKENDS = [("local", None), ("pallas", None),
            ("sharded", (1, 1)), ("sharded", (2, 4)), ("sharded", (8, 1))]


def _matcher(backend, shape, **kw):
    if backend == "sharded":
        n = shape[0] * shape[1]
        if len(jax.devices()) < n:
            pytest.skip(f"needs {n} host devices (conftest forces 8)")
        kw["mesh"] = make_matcher_mesh(shape=shape)
    dfas = [make_search_dfa(compile_regex(p)) for p in PATTERNS]
    return Matcher(dfas, backend=backend, batch_tile=8, **kw)


def _doc(rng, n):
    return bytes(rng.choice(ALPHABET) for _ in range(n))


def _segments(rng, doc, *, max_seg=7, with_empty=True):
    segs, i = [], 0
    while i < len(doc):
        n = rng.randint(1, max_seg)
        segs.append(doc[i:i + n])
        i += n
    if with_empty and rng.random() < 0.5:
        # empties may land anywhere; offsets stay consistent (cumsum adds 0)
        segs.insert(rng.randint(0, len(segs)), b"")
    assert b"".join(segs) == doc
    return segs


def _offsets(segs):
    return np.concatenate([[0], np.cumsum([len(s) for s in segs])]).astype(int)


def _oracle(m, doc):
    starts = m.packed.starts.astype(np.int32)[None]
    return m.advance_segments([doc], starts).final_states[0]


def _feed_permuted(ooo, segs, doc, order, rng, *, hints, dup_rate=0.0):
    s = ooo.open()
    offs = _offsets(segs)
    for i in order:
        tail = doc[max(0, offs[i] - 2):offs[i]] if hints else None
        s.feed(i, segs[i], prev_tail=tail)
        if dup_rate and rng.random() < dup_rate:
            s.feed(i, segs[i], prev_tail=tail)
    return s


# --------------------------------------------------------------------------
# the scan-compose primitive
# --------------------------------------------------------------------------

def test_scan_compose_matches_sequential_ref():
    m = _matcher("local", None)
    dev, t = m.dev, m.dev.tables
    rng = random.Random(7)
    for _ in range(10):
        doc = _doc(rng, rng.randint(8, 40))
        offs = list(range(4, len(doc), 4))  # >= 4 bytes before every cut:
        segs = [doc[a:b]                    # boundary keys valid for r <= 2
                for a, b in zip([0] + offs, offs + [len(doc)])]
        maps, keys = [], []
        for i in range(1, len(segs)):
            cls = dev.advance_key(-1, doc[offs[i - 1] - 2:offs[i - 1]])
            assert cls >= 0
            r = segment_result(dev, segs[i], cls)
            maps.append(np.broadcast_to(
                r.lane_states, (m.packed.n_patterns, t.i_max)))
            keys.append(cls)
        if not maps:
            continue
        lanes = np.stack(maps)[None].astype(np.int32)
        ks = np.array(keys, np.int32)[None]
        ref = kref.spec_merge_lanes_scan_ref(
            lanes, ks, np.asarray(t.cand_index), np.asarray(m.packed.sinks),
            pad_cls=dev.pad_key)
        out = np.asarray(merge_scan_lanes_jnp(
            lanes, ks, dev.cidx_pad_j, dev.sinks_j,
            pad_key=dev.pad_key, axis=1))
        np.testing.assert_array_equal(out, ref)


def test_compose_lane_maps_one_dispatch_equals_whole_doc():
    m = _matcher("local", None)
    dev = m.dev
    rng = random.Random(3)
    for _ in range(5):
        doc = _doc(rng, rng.randint(12, 50))
        segs = [doc[i:i + 4] for i in range(0, len(doc), 4)]
        n, k, s = len(segs), m.packed.n_patterns, dev.i_max
        # row = [exact seed advanced through seg 0] + maps of segs 1..n-1
        lanes = np.zeros((1, n, k, s), np.int32)
        keys = np.full((1, n), dev.pad_key, np.int32)
        seed = m.advance_segments(
            [segs[0]], m.packed.starts.astype(np.int32)[None])
        lanes[0, 0] = seed.final_states[0][:, None]
        for i in range(1, n):
            cls = dev.advance_key(-1, doc[4 * i - 2:4 * i])
            r = segment_result(dev, segs[i], cls)
            lanes[0, i] = np.broadcast_to(r.lane_states, (k, s))
            keys[0, i] = cls
        before = m.compose_calls
        out = m.compose_lane_maps(lanes, keys)
        assert m.compose_calls == before + 1
        np.testing.assert_array_equal(out[0, :, 0], _oracle(m, doc))


# --------------------------------------------------------------------------
# permutation bit-identity, all backends / meshes
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend,shape", BACKENDS,
                         ids=[f"{b}-{s}" for b, s in BACKENDS])
def test_permutation_bit_identity(backend, shape):
    m = _matcher(backend, shape)
    ooo = OooStreamMatcher(m, policy=OooPolicy(match_batch=4))
    rng = random.Random(11)
    base = merge_calls()
    for trial in range(6):
        doc = _doc(rng, rng.randint(0, 48))
        segs = _segments(rng, doc)
        order = list(range(len(segs)))
        rng.shuffle(order)
        s = _feed_permuted(ooo, segs, doc, order, rng,
                           hints=(trial % 2 == 0), dup_rate=0.3)
        res = s.close()
        np.testing.assert_array_equal(res.final_states, _oracle(m, doc))
        np.testing.assert_array_equal(
            res.accepted, m.packed.accepting[_oracle(m, doc)])
        assert res.byte_count == len(doc)
    assert merge_calls() == base, "host-side merge on the ooo data path"
    assert ooo.stats.scan_folds <= ooo.stats.gap_closes


def test_property_permutations_and_duplicates():
    """Hypothesis property when installed; the seeded sweep always runs."""
    m = _matcher("local", None)

    def run_case(doc, cuts, order_seed, dup_every):
        segs = [doc[a:b] for a, b in zip([0] + cuts, cuts + [len(doc)])]
        order = list(range(len(segs)))
        random.Random(order_seed).shuffle(order)
        ooo = OooStreamMatcher(m)
        rng = random.Random(order_seed)
        s = ooo.open()
        offs = _offsets(segs)
        for j, i in enumerate(order):
            tail = doc[max(0, offs[i] - 2):offs[i]] if i % 2 else None
            s.feed(i, segs[i], prev_tail=tail)
            if dup_every and j % dup_every == 0:
                s.feed(i, segs[i])
        ooo.flush()
        fp = ooo._streams[s.sid].stream_fp  # pre-close: composed so far
        res = s.close()
        np.testing.assert_array_equal(res.final_states, _oracle(m, doc))
        assert compose_fingerprints(
            fp, segment_fingerprint(b""), 0) == fp  # identity sanity
        return res

    rng = random.Random(23)
    for _ in range(8):
        doc = _doc(rng, rng.randint(0, 40))
        cuts = sorted(rng.sample(range(len(doc) + 1),
                                 min(len(doc), rng.randint(0, 6))))
        run_case(doc, cuts, rng.randint(0, 999), rng.choice([0, 2, 3]))

    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(doc=st.binary(max_size=32).map(
               lambda b: bytes(ALPHABET[x % len(ALPHABET)] for x in b)),
           data=st.data())
    def prop(doc, data):
        cuts = sorted(data.draw(st.lists(
            st.integers(0, len(doc)), max_size=5)))
        run_case(doc, cuts, data.draw(st.integers(0, 10_000)),
                 data.draw(st.sampled_from([0, 2])))

    prop()


def test_stream_fingerprint_matches_whole_doc():
    m = _matcher("local", None)
    ooo = OooStreamMatcher(m)
    rng = random.Random(5)
    doc = _doc(rng, 33)
    segs = _segments(rng, doc)
    s = _feed_permuted(ooo, segs, doc, list(reversed(range(len(segs)))),
                       rng, hints=False)
    ooo.flush()
    assert ooo._streams[s.sid].stream_fp == segment_fingerprint(doc)
    s.close()
    assert segment_fingerprint(b"\x00" + doc) == segment_fingerprint(doc), \
        "leading-zero blindness is WHY comparisons pair fp with n_bytes"
    assert compose_fingerprints(
        segment_fingerprint(doc[:7]), segment_fingerprint(doc[7:]),
        len(doc) - 7) == segment_fingerprint(doc)
    assert 0 <= segment_fingerprint(doc) < FP_MOD


# --------------------------------------------------------------------------
# dispatch discipline: one scan per gap close, batched spec matching
# --------------------------------------------------------------------------

def test_gap_close_is_one_scan_dispatch():
    m = _matcher("local", None)
    ooo = OooStreamMatcher(m, policy=OooPolicy(match_batch=1))
    rng = random.Random(2)
    doc = b"ab0189ba" * 4
    segs = [doc[i:i + 4] for i in range(0, len(doc), 4)]
    offs = _offsets(segs)
    s = ooo.open()
    for i in range(1, len(segs)):
        s.feed(i, segs[i], prev_tail=doc[offs[i] - 2:offs[i]], flush=True)
    assert ooo.stats.spec_matched == len(segs) - 1
    assert s.buffered_bytes == 0, "matched payloads must be released"
    folds = ooo.stats.scan_folds
    s.feed(0, segs[0], flush=True)
    assert ooo.stats.scan_folds == folds + 1, \
        "closing the gap must fold the whole run in ONE scan dispatch"
    assert ooo.stats.scan_fold_segments >= len(segs) - 1
    assert ooo.stats.scan_batch > 1
    res = s.close()
    np.testing.assert_array_equal(res.final_states, _oracle(m, doc))


def test_in_order_streams_never_park():
    m = _matcher("local", None)
    ooo = OooStreamMatcher(m, policy=OooPolicy(match_batch=1))
    s = ooo.open()
    for i, seg in enumerate([b"ab01", b"89ba", b"xy"]):
        s.feed(i, seg, flush=True)
        assert s.buffered_segments == 0
    assert ooo.stats.spec_matched == 0, "in-order rides the exact path"
    assert ooo.stats.scan_folds == 0
    assert ooo.stats.exact_segments == 3
    s.close()


# --------------------------------------------------------------------------
# duplicates, integrity, backpressure, gaps
# --------------------------------------------------------------------------

def test_duplicate_deliveries_dedup_and_conflict():
    m = _matcher("local", None)
    ooo = OooStreamMatcher(m, policy=OooPolicy(match_batch=1))
    s = ooo.open()
    s.feed(0, b"ab01", flush=True)          # folded
    s.feed(0, b"ab01")                      # late duplicate of folded seq
    s.feed(2, b"xy")                        # parked
    s.feed(2, b"xy")                        # duplicate of parked seq
    assert ooo.stats.duplicates == 2
    assert s.buffered_segments == 1
    with pytest.raises(OooIntegrityError):
        s.feed(0, b"abXX")                  # folded seq, different content
    with pytest.raises(OooIntegrityError):
        s.feed(2, b"xY")                    # parked seq, different content
    with pytest.raises(OooIntegrityError):
        # hint contradicts the actual predecessor bytes ("01" keys class
        # pairs differently than the claimed "xy")
        s.feed(1, b"89", prev_tail=b"xy")
        ooo.flush()
        s.feed(1, b"89")  # unreachable when the hint check fires at resolve
    ooo2 = OooStreamMatcher(m)
    s2 = ooo2.open()
    with pytest.raises(ValueError):
        s2.feed(0, b"ab", prev_tail=b"x")   # nothing precedes segment 0
    with pytest.raises(ValueError):
        s2.feed(-1, b"ab")


def test_backpressure_bounded_buffer():
    m = _matcher("local", None)
    ooo = OooStreamMatcher(
        m, policy=OooPolicy(max_buffered_segments=4, match_batch=1000))
    s = ooo.open()
    for i in range(1, 5):
        s.feed(i, b"ab")
    with pytest.raises(ReorderBufferFull) as exc:
        s.feed(5, b"ba")
    assert exc.value.seq_no == 5 and exc.value.stream_id == s.sid
    assert s.buffered_segments == 4, "refused admission must not mutate"
    s.feed(0, b"xy")  # frontier bypasses caps and drains at the next flush
    ooo.flush()
    assert s.buffered_segments == 0
    s.feed(5, b"ba")  # redelivery after backpressure now admits
    s.close()
    bytes_pol = OooPolicy(max_buffered_bytes=8, match_batch=1000,
                          dedup_window=0)
    ooo2 = OooStreamMatcher(m, policy=bytes_pol)
    s2 = ooo2.open()
    s2.feed(3, b"abababab")  # 8 raw bytes parked, no hint -> stays raw
    with pytest.raises(ReorderBufferFull):
        s2.feed(4, b"x")
    with pytest.raises(ValueError):
        OooPolicy(max_buffered_segments=0)
    with pytest.raises(ValueError):
        OooPolicy(dedup_window=-1)


def test_close_with_gap_raises():
    m = _matcher("local", None)
    ooo = OooStreamMatcher(m)
    s = ooo.open()
    s.feed(0, b"ab")
    s.feed(2, b"ba")
    with pytest.raises(SequenceGapError, match="seq 1 never arrived"):
        s.close()
    s.feed(1, b"01")
    res = s.close()
    np.testing.assert_array_equal(res.final_states, _oracle(m, b"ab01ba"))
    with pytest.raises(ValueError):
        s.feed(3, b"x")  # closed stream


def test_zero_byte_segments_and_absorbed_skip():
    m = _matcher("local", None)
    ooo = OooStreamMatcher(m, policy=OooPolicy(match_batch=1))
    s = ooo.open()
    s.feed(0, b"", flush=True)
    s.feed(2, b"")
    s.feed(1, b"abba", flush=True)
    res = s.close()
    np.testing.assert_array_equal(res.final_states, _oracle(m, b"abba"))
    # fully absorbed stream: payloads are never parked nor matched
    doc = b"abba" + b"012" + b"xxy"  # all three patterns absorb after this
    s2 = ooo.open()
    s2.feed(0, doc, flush=True)
    skips = ooo.stats.absorbed_skips
    s2.feed(2, b"9999ab")
    s2.feed(1, b"xyxy01", flush=True)
    assert ooo.stats.absorbed_skips >= skips + 2
    res2 = s2.close()
    assert res2.accepted.all()
    assert res2.byte_count == len(doc) + 12
    np.testing.assert_array_equal(
        res2.final_states, _oracle(m, doc + b"xyxy019999ab"))


def test_early_accepts_before_sequencing():
    m = _matcher("local", None)
    ooo = OooStreamMatcher(m, policy=OooPolicy(match_batch=1))
    s = ooo.open()
    # segment 2 arrives first, carrying a full ".*[0-9]{3}" hit with its
    # boundary hint -> decided before segments 0 and 1 ever land
    s.feed(2, b"z0189zz", prev_tail=b"qq", flush=True)
    dec = s.early_accepts()
    assert dec[PATTERNS.index(".*[0-9]{3}")]
    assert not dec.all()
    s.feed(0, b"zz", flush=True)
    s.feed(1, b"qq", flush=True)
    res = s.close()
    assert res.accepted[PATTERNS.index(".*[0-9]{3}")]


# --------------------------------------------------------------------------
# failover: snapshot/restore mid-reorder, cross-mesh
# --------------------------------------------------------------------------

@pytest.mark.parametrize("src,dst", [
    (("local", None), ("local", None)),
    (("local", None), ("sharded", (2, 4))),
    (("sharded", (8, 1)), ("local", None)),
])
def test_snapshot_restore_mid_reorder(tmp_path, src, dst):
    m1 = _matcher(*src)
    m2 = m1 if src == dst else _matcher(*dst)
    ooo = OooStreamMatcher(m1, policy=OooPolicy(match_batch=1000))
    rng = random.Random(17)
    doc = _doc(rng, 44)
    segs = _segments(rng, doc, with_empty=False)
    offs = _offsets(segs)
    s = ooo.open()
    for i in range(1, len(segs), 2):  # gaps + a mix of matched/raw parks
        hint = doc[max(0, offs[i] - 2):offs[i]] if i % 4 == 1 else None
        s.feed(i, segs[i], prev_tail=hint)
    ooo.flush()
    assert s.buffered_segments > 0
    tree = ooo_tree(ooo)
    assert set(tree) == set(OOO_TREE_KEYS)
    ooo.snapshot(str(tmp_path))
    ooo2 = OooStreamMatcher(m2, policy=ooo.policy)
    (s2,) = ooo2.restore(str(tmp_path))
    assert (s2.sid, s2.next_seq, s2.buffered_segments) == \
        (s.sid, s.next_seq, s.buffered_segments)
    for owner, h in ((ooo, s), (ooo2, s2)):
        for i in range(0, len(segs), 2):
            h.feed(i, segs[i])
    r1, r2 = s.close(), s2.close()
    np.testing.assert_array_equal(r1.final_states, r2.final_states)
    np.testing.assert_array_equal(r1.final_states, _oracle(m1, doc))
    assert r1.byte_count == r2.byte_count == len(doc)


def test_restore_refuses_foreign_tables(tmp_path):
    m = _matcher("local", None)
    ooo = OooStreamMatcher(m)
    ooo.open().feed(1, b"ab")
    ooo.snapshot(str(tmp_path))
    other = Matcher([make_search_dfa(compile_regex(".*zz"))],
                    backend="local", batch_tile=8)
    with pytest.raises(ValueError, match="different packed pattern set"):
        OooStreamMatcher(other).restore(str(tmp_path))


# --------------------------------------------------------------------------
# the scheduler twin: candidate-keyed sessions across ticks
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend,shape",
                         [("local", None), ("sharded", (2, 4))],
                         ids=["local", "sharded-2x4"])
def test_lane_ticks_scheduler_matches_host_reference(backend, shape):
    m = _matcher(backend, shape)
    sm = StreamMatcher(m, lane_ticks=True)
    rng = random.Random(9)
    dev = m.dev
    classes = list(range(min(4, dev.n_keys)))
    plans = {cls: [_doc(rng, rng.randint(0, 9)) for _ in range(3)]
             for cls in classes}
    base = merge_calls()
    got = {}
    for cls in classes:
        sess = sm.open_at(cls)
        for seg in plans[cls]:
            sess.feed(seg)
        got[cls] = sm.close_map(sess)
    assert merge_calls() == base, "lane ticks must not compose on host"
    for cls in classes:
        want = open_lane_cursor(dev, cls)
        for seg in plans[cls]:
            want = merge(want, segment_result(dev, seg, want.last_class),
                         tables=dev)
        np.testing.assert_array_equal(got[cls].lane_states, want.lane_states)
        assert got[cls].entry_class == cls
        assert got[cls].n_bytes == want.byte_count
    with pytest.raises(ValueError, match="lane_ticks"):
        StreamMatcher(m).open_at(0)

# --------------------------------------------------------------------------
# cross-stream dedup: compute dedup, never drop dedup (PR 10)
# --------------------------------------------------------------------------

def test_cross_stream_dedup_bit_identical_and_hits():
    """Identical content replayed on many streams reuses the matched map
    (cross_stream_hits) without changing any stream's decision."""
    rng = random.Random(11)
    m = _matcher("local", None)
    doc = _doc(rng, 40)
    segs = _segments(rng, doc, with_empty=False)
    offs = _offsets(segs)
    order = list(range(len(segs)))[::-1]  # every non-frontier seg parks
    n_streams = 4
    results = {}
    for window in (0, 64):
        pol = OooPolicy(match_batch=4, cross_stream_dedup_window=window)
        ooo = OooStreamMatcher(_matcher("local", None), policy=pol)
        streams = [ooo.open() for _ in range(n_streams)]
        for i in order:
            tail = doc[max(0, offs[i] - 2):offs[i]]
            for s in streams:
                s.feed(i, segs[i], prev_tail=tail)
            ooo.flush()
        results[window] = [s.close() for s in streams]
        if window:
            assert ooo.stats.cross_stream_hits > 0
            # the reused maps dispatched fewer device rows, not fewer answers
            assert ooo.stats.spec_matched < n_streams * len(order)
        else:
            assert ooo.stats.cross_stream_hits == 0
    want = _oracle(m, doc)
    for window, res in results.items():
        for r in res:
            np.testing.assert_array_equal(r.final_states, want,
                                          err_msg=f"window={window}")


def test_cross_stream_dedup_keys_on_boundary_key():
    """Same bytes at a different boundary key must NOT share a map."""
    from repro.streaming.ooo.fingerprint import FingerprintWindow

    w = FingerprintWindow(8)
    w.put(123, 4, 2, "map-at-key-2")
    assert w.get(123, 4, 2) == "map-at-key-2"
    assert w.get(123, 4, 3) is None          # other key: miss
    assert w.get(123, 5, 2) is None          # other length: miss
    assert w.hits == 1 and w.misses == 2


def test_fingerprint_window_lru_bound():
    from repro.streaming.ooo.fingerprint import FingerprintWindow

    w = FingerprintWindow(2)
    w.put(1, 1, 0, "a")
    w.put(2, 1, 0, "b")
    assert w.get(1, 1, 0) == "a"             # refresh 1 -> 2 is LRU
    w.put(3, 1, 0, "c")                      # evicts 2
    assert len(w) == 2
    assert w.get(2, 1, 0) is None
    assert w.get(1, 1, 0) == "a" and w.get(3, 1, 0) == "c"
    with pytest.raises(ValueError):
        FingerprintWindow(0)


def test_cross_stream_window_not_persisted():
    """The window is ephemeral: policy round-trips through a checkpoint but
    the cached maps do not (a restored matcher refills as traffic flows)."""
    import tempfile

    pol = OooPolicy(match_batch=1, cross_stream_dedup_window=16)
    ooo = OooStreamMatcher(_matcher("local", None), policy=pol)
    s = ooo.open()
    s.feed(1, b"abab", prev_tail=b"xy")      # parks + matches via window path
    assert ooo._xwindow is not None and len(ooo._xwindow) > 0
    with tempfile.TemporaryDirectory() as d:
        ooo.snapshot(d)
        fresh = OooStreamMatcher(_matcher("local", None), policy=pol)
        fresh.restore(d)
        assert fresh._xwindow is not None and len(fresh._xwindow) == 0
