"""Per-architecture smoke tests: reduced config, one forward/train/decode step
on CPU, asserting output shapes and finiteness.  Full configs are exercised
only via the dry-run (ShapeDtypeStruct, no allocation)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec, get_config, list_archs, reduce_for_smoke
from repro.models import api
from repro.models.transformer import lm_loss

ARCHS = list_archs()

SMOKE_TRAIN = ShapeSpec("smoke_train", "train", 64, 2)
SMOKE_PREFILL = ShapeSpec("smoke_prefill", "prefill", 64, 2)
SMOKE_DECODE = ShapeSpec("smoke_decode", "decode", 64, 2)


def _setup(arch):
    cfg = reduce_for_smoke(get_config(arch))
    params = api.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_registry_has_all_ten():
    assert len(ARCHS) == 10, ARCHS


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expect = {
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expect


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg, params = _setup(arch)
    batch = api.make_inputs(cfg, SMOKE_TRAIN, seed=1)

    def loss_fn(p):
        logits, aux = api.train_logits(p, cfg, batch)
        return lm_loss(logits, batch["labels"]) + 0.01 * aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), arch
    leaves = jax.tree.leaves(grads)
    assert leaves and all(np.isfinite(np.asarray(g)).all() for g in leaves), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, params = _setup(arch)
    batch = api.make_inputs(cfg, SMOKE_TRAIN, seed=2)
    logits, aux = jax.jit(lambda p, b: api.train_logits(p, cfg, b))(params, batch)
    b, t = batch["tokens"].shape
    assert logits.shape == (b, t, cfg.padded_vocab), (arch, logits.shape)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_smoke(arch):
    cfg, params = _setup(arch)
    batch = api.make_inputs(cfg, SMOKE_PREFILL, seed=3)
    logits, cache = jax.jit(lambda p, b: api.prefill(p, cfg, b))(params, batch)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch):
    cfg, params = _setup(arch)
    batch = api.make_inputs(cfg, SMOKE_DECODE, seed=4)
    logits, new_cache = jax.jit(lambda p, b: api.decode(p, cfg, b))(params, batch)
    b = batch["tokens"].shape[0]
    assert logits.shape == (b, 1, cfg.padded_vocab), (arch, logits.shape)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all(), arch
    assert new_cache is not None


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "recurrentgemma-2b", "xlstm-1.3b"])
def test_decode_matches_forward(arch):
    """Greedy decode over a short prompt must match teacher-forced logits."""
    cfg, params = _setup(arch)
    rng = np.random.default_rng(0)
    b, t = 2, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, t)), jnp.int32)
    full_logits, _ = api.train_logits(params, cfg, {"tokens": tokens})

    if cfg.family in ("hybrid", "ssm"):
        from repro.models import recurrent as RG
        from repro.models import xlstm as XL
        mod = RG if cfg.family == "hybrid" else XL
        if cfg.family == "hybrid":
            state = RG.init_hybrid_state(cfg, b)
            step = RG.decode_step_hybrid
        else:
            state = XL.init_xlstm_state(cfg, b)
            step = XL.decode_step_xlstm
        outs = []
        for i in range(t):
            logits, state = step(params, cfg, state, tokens[:, i : i + 1],
                                 jnp.int32(i))
            outs.append(logits[:, 0])
        dec = jnp.stack(outs, axis=1)
    else:
        from repro.models import transformer as TF
        cache = TF.init_cache(cfg, b, t)
        outs = []
        for i in range(t):
            logits, cache = TF.decode_step(params, cfg, cache,
                                           tokens[:, i : i + 1], jnp.int32(i))
            outs.append(logits[:, 0])
        dec = jnp.stack(outs, axis=1)

    # ssm: the chunkwise-parallel path stores bf16 score tiles (§Perf it. 4)
    # while decode is fp32 — wider envelope, same argmax behaviour
    atol = 0.15 if cfg.family == "ssm" else 3e-2
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=3e-2, atol=atol)
    agree = (np.argmax(np.asarray(dec, np.float32), -1)
             == np.argmax(np.asarray(full_logits, np.float32), -1)).mean()
    assert agree > 0.9, agree
